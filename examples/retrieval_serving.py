"""End-to-end serving driver (deliverable b): serve a small collection
with batched requests through the unified ``repro.serve.api`` surface.

Builds SPLADE + LILSR collections, constructs a Seismic index and an
HNSW graph over the same forward index, runs batched search with every
codec registered in ``core/layout.py`` — uncompressed, DotVByte,
StreamVByte and bitpack rows — and reports recall / per-query latency /
index bytes: the serving analogue of the paper's Table 2, plus the
graph-vs-inverted-index comparison of EXPERIMENTS.md §Graph.

Run:  PYTHONPATH=src python examples/retrieval_serving.py [--n-docs 8000]
(the HNSW host build is a few ms per doc; use --no-hnsw to skip it)
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.hnsw import HNSWIndex, HNSWParams
from repro.core.layout import available_layouts
from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
from repro.data.synthetic import generate_collection, lilsr_config, splade_config
from repro.serve.api import Retriever, RetrieverConfig

CODECS = available_layouts()


def _serve(name, retriever, Q, truth, col, k):
    ids, _ = retriever.search(Q)  # warm-up / compile
    t0 = time.perf_counter()
    ids, _ = retriever.search(Q)
    np.asarray(ids)
    dt = (time.perf_counter() - t0) * 1e6 / Q.shape[0]
    rec = np.mean([recall_at_k(truth[i], np.asarray(ids[i]))
                   for i in range(Q.shape[0])])
    codec = retriever.cfg.codec
    comp = col.fwd.storage_bytes(codec)["components"]
    print(f"  {name:8s} {codec:13s} recall@{k}={rec:.3f} "
          f"{dt:8.0f} µs/query (CPU)  components={comp/2**20:6.2f} MiB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=6000)
    ap.add_argument("--n-queries", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--no-hnsw", action="store_true",
                    help="skip the graph-engine section (faster)")
    args = ap.parse_args()

    for enc, cfg_fn in (("splade", splade_config), ("lilsr", lilsr_config)):
        print(f"\n=== {enc}: {args.n_docs} docs ===")
        col = generate_collection(cfg_fn(args.n_docs, args.n_queries, seed=0),
                                  value_format="f16")
        index = SeismicIndex.build(col.fwd, SeismicParams(n_postings=1500, block_size=64))
        Q = jnp.asarray(np.stack([col.query_dense(i) for i in range(args.n_queries)]))
        truth = [exact_top_k(col.fwd, np.asarray(Q[i]), args.k)[0]
                 for i in range(args.n_queries)]

        for codec in CODECS:
            r = Retriever.from_host_index(
                index,
                RetrieverConfig(engine="seismic", codec=codec, k=args.k,
                                params=dict(cut=8, block_budget=512, n_probe=96)))
            _serve("seismic", r, Q, truth, col, args.k)

        if args.no_hnsw:
            continue
        graph = HNSWIndex.build(col.fwd, HNSWParams(m=16, ef_construction=48))
        for codec in CODECS:
            r = Retriever.from_host_index(
                graph,
                RetrieverConfig(engine="hnsw", codec=codec, k=args.k,
                                params=dict(beam=96, iters=96, n_seeds=8)))
            _serve("hnsw", r, Q, truth, col, args.k)


if __name__ == "__main__":
    main()
