"""Fault-tolerance demo (deliverable b, extra): train a reduced LM with
injected failures and show checkpoint/restart recovery producing the
same final state as a fault-free run — the property that makes the
framework deployable on preemptible fleets.

Run:  PYTHONPATH=src python examples/elastic_training_demo.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tf_m
from repro.train.elastic import FaultInjector, Runner, RunnerConfig
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_train_state, make_train_step


def main() -> None:
    arch = get_arch("qwen3-8b")
    cfg = arch.smoke_cfg
    key = jax.random.PRNGKey(0)
    params = tf_m.init_params(key, cfg)
    oinit, oupd = make_optimizer(arch.optimizer)
    step = jax.jit(make_train_step(
        lambda p, b: tf_m.lm_loss(p, cfg, b["tokens"], b["labels"]), oupd))

    def batch_fn(i):
        kk = jax.random.fold_in(key, i)
        toks = jax.random.randint(kk, (8, 33), 0, cfg.vocab)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def run(faults):
        with tempfile.TemporaryDirectory() as d:
            runner = Runner(
                RunnerConfig(total_steps=60, checkpoint_dir=d, checkpoint_every=10),
                step, batch_fn, init_train_state(params, oinit),
                fault_injector=FaultInjector(fail_at=faults),
            )
            state, hist = runner.run()
            return state, hist, runner.restarts

    print("fault-free run…")
    s0, h0, r0 = run(())
    print(f"  60 steps, restarts={r0}, final loss={h0[-1]['loss']:.4f}")

    print("run with injected faults at steps 17 and 41…")
    s1, h1, r1 = run((17, 41))
    print(f"  {len(h1)} step records (incl. replays), restarts={r1}, "
          f"final loss={h1[-1]['loss']:.4f}")

    diff = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(s0["params"]), jax.tree.leaves(s1["params"]))
    )
    print(f"max |param diff| fault-free vs recovered: {diff:.2e} "
          f"({'EXACT' if diff == 0 else 'deterministic replay within fp tolerance'})")


if __name__ == "__main__":
    main()
