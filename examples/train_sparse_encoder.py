"""End-to-end training driver (deliverable b): train a SPLADE-style
sparse encoder (~100M params at default size) for a few hundred steps
with the fault-tolerant runner, then index its embeddings with Seismic +
DotVByte and measure retrieval recall — the full lifecycle the paper's
technique lives in: encoder → sparse embeddings → compressed forward
index → ANNS.

Defaults are CPU-sized; ``--full`` selects the ~100M-param configuration
(vocab 30522, 8 layers, d=512) exercised per-step identically.

Run:  PYTHONPATH=src python examples/train_sparse_encoder.py --steps 200
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forward_index import ForwardIndex
from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
from repro.models.common import count_params
from repro.models.sparse_encoder import SparseEncoderConfig, contrastive_loss, encode, encoder_init
from repro.train.elastic import Runner, RunnerConfig
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.train_step import init_train_state, make_train_step


def synth_pairs(key, step, cfg, batch=16, seq=24, n_topics=64):
    """Deterministic (query, doc) token pairs sharing a latent topic:
    tokens are drawn from a topic-specific vocabulary slice, so matching
    pairs share vocabulary — the signal the contrastive loss learns."""
    kk = jax.random.fold_in(key, step)
    ks = jax.random.split(kk, 4)
    topic = jax.random.randint(ks[0], (batch,), 0, n_topics)
    width = cfg.vocab // n_topics
    lo = topic[:, None] * width

    def draw(k, length):
        off = jax.random.randint(k, (batch, length), 0, width)
        return (lo + off).astype(jnp.int32)

    return {
        "q_tokens": draw(ks[1], seq), "q_mask": jnp.ones((batch, seq), bool),
        "d_tokens": draw(ks[2], seq), "d_mask": jnp.ones((batch, seq), bool),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true", help="~100M-param config")
    ap.add_argument("--n-docs", type=int, default=1500)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (
        SparseEncoderConfig()  # vocab 30522, 8L, d512 ≈ 100M params
        if args.full
        else SparseEncoderConfig(vocab=4096, n_layers=4, d_model=128, n_heads=4,
                                 d_ff=512, max_len=32, flops_lambda=3e-4)
    )
    key = jax.random.PRNGKey(args.seed)
    params = encoder_init(key, cfg)
    print(f"encoder params: {count_params(params)/1e6:.1f}M")

    oinit, oupd = make_optimizer(OptimizerConfig(lr=1e-3, warmup_steps=20,
                                                 total_steps=args.steps))
    step = jax.jit(make_train_step(lambda p, b: contrastive_loss(p, cfg, b), oupd))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        runner = Runner(
            RunnerConfig(total_steps=args.steps, checkpoint_dir=ckpt_dir,
                         checkpoint_every=50),
            step, lambda i: synth_pairs(key, i, cfg), init_train_state(params, oinit),
        )
        state, hist = runner.run()
    print(f"loss {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f} over {len(hist)} steps")

    # --- encode a corpus and retrieve through the compressed index -------
    print("encoding corpus + queries…")
    enc = jax.jit(lambda p, t, m: encode(p, cfg, t, m))
    docs, queries = [], []
    for i in range(args.n_docs // 16):
        b = synth_pairs(key, 10_000 + i, cfg)
        d_emb = np.asarray(enc(state["params"], b["d_tokens"], b["d_mask"]))
        q_emb = np.asarray(enc(state["params"], b["q_tokens"], b["q_mask"]))
        for j in range(d_emb.shape[0]):
            c = np.flatnonzero(d_emb[j]).astype(np.uint32)
            if len(c) == 0:
                c = np.array([0], np.uint32)
            docs.append((c, d_emb[j][c]))
        if i < 2:  # 32 queries
            queries.extend(list(q_emb))

    fwd = ForwardIndex.from_docs(docs, cfg.vocab, value_format="f16")
    nnz = fwd.total_nnz / fwd.n_docs
    print(f"corpus: {fwd.n_docs} docs, learned sparsity {nnz:.0f} nnz/doc")
    comp_c = fwd.storage_bytes("dotvbyte")["components"]
    comp_u = fwd.storage_bytes("uncompressed")["components"]
    print(f"forward index components: {comp_u/2**10:.0f} KiB raw → "
          f"{comp_c/2**10:.0f} KiB DotVByte ({8*comp_c/max(fwd.total_nnz,1):.1f} bits/comp)")

    index = SeismicIndex.build(fwd, SeismicParams(n_postings=800, block_size=32))
    index.prepare_codec("dotvbyte")
    recs = []
    for q in queries:
        true_ids, _ = exact_top_k(fwd, q, 10)
        got_ids, _ = index.search(q, k=10, heap_factor=0.9, cut=8, codec="dotvbyte")
        recs.append(recall_at_k(true_ids, got_ids))
    print(f"Seismic recall@10 with DotVByte rescoring: {np.mean(recs):.3f}")


if __name__ == "__main__":
    main()
