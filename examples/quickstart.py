"""Quickstart: the paper's pipeline end to end in ~60 seconds on CPU.

1. generate a synthetic SPLADE-statistics collection;
2. build the forward index; compress components with every codec and
   compare bits/component (Table 1's size axis);
3. apply RGB re-ordering and show the compression improvement;
4. build a Seismic index; search with DotVByte-compressed rescoring and
   verify recall@10 against exact search.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.codecs import available_codecs, get_codec
from repro.core.rgb import apply_permutation_dense, recursive_graph_bisection
from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
from repro.data.synthetic import generate_collection, splade_config


def main() -> None:
    print("=== 1. synthetic SPLADE collection (MsMarco statistics) ===")
    col = generate_collection(splade_config(n_docs=4000, n_queries=16, seed=0))
    fwd = col.fwd
    print(f"  {fwd.n_docs} docs, dim={fwd.dim}, nnz/doc={fwd.total_nnz/fwd.n_docs:.0f}")

    print("\n=== 2. components compression (paper §2, Table 1 size axis) ===")
    docs = [fwd.components[int(s):int(e)]
            for s, e in zip(fwd.offsets[:-1], fwd.offsets[1:])]
    for name in available_codecs():
        bpc = get_codec(name).bits_per_component(docs)
        print(f"  {name:13s} {bpc:5.2f} bits/component")

    print("\n=== 3. RGB re-ordering (paper §2) ===")
    pi = recursive_graph_bisection(docs, fwd.dim, max_iters=5, leaf_size=32)
    fwd_rgb = fwd.apply_component_permutation(pi)
    docs_rgb = [fwd_rgb.components[int(s):int(e)]
                for s, e in zip(fwd_rgb.offsets[:-1], fwd_rgb.offsets[1:])]
    for name in ("elias_gamma", "zeta", "dotvbyte"):
        b0 = get_codec(name).bits_per_component(docs)
        b1 = get_codec(name).bits_per_component(docs_rgb)
        print(f"  {name:13s} {b0:5.2f} → {b1:5.2f} bits/component "
              f"({100*(1-b1/b0):+.0f}%)")

    print("\n=== 4. Seismic + compressed forward index (paper §3) ===")
    index = SeismicIndex.build(fwd, SeismicParams(n_postings=1000, block_size=32))
    index.prepare_codec("dotvbyte")
    recalls = []
    for i in range(col.n_queries):
        q = col.query_dense(i)
        true_ids, _ = exact_top_k(fwd, q, 10)
        got_ids, _ = index.search(q, k=10, heap_factor=0.9, cut=8, codec="dotvbyte")
        recalls.append(recall_at_k(true_ids, got_ids))
    sizes_c = index.index_bytes("dotvbyte")
    sizes_u = index.index_bytes("uncompressed")
    print(f"  recall@10 = {np.mean(recalls):.3f} with DotVByte rescoring")
    print(f"  forward-index components: {sizes_u['forward_components']/2**20:.2f} MiB → "
          f"{sizes_c['forward_components']/2**20:.2f} MiB "
          f"({100*(1-sizes_c['forward_components']/sizes_u['forward_components']):.0f}% saved)")
    print(f"  total index: {sizes_u['total']/2**20:.1f} → {sizes_c['total']/2**20:.1f} MiB "
          f"(summaries/inverted dominate at this toy scale; at MsMarco scale "
          f"the forward index dominates, as in the paper's Table 2)")


if __name__ == "__main__":
    main()
