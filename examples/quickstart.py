"""Quickstart: the paper's pipeline end to end in ~60 seconds on CPU.

1. generate a synthetic SPLADE-statistics collection;
2. build the forward index; compress components with every codec and
   compare bits/component (Table 1's size axis);
3. apply RGB re-ordering and show the compression improvement;
4. serve through the unified Retriever API (DESIGN.md §7): build a
   DotVByte-compressed Seismic retriever, verify recall@10 against
   exact search, then save the index artifact and reopen it —
   build/serve split, byte-identical top-k.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.core.codecs import available_codecs, get_codec
from repro.core.rgb import apply_permutation_dense, recursive_graph_bisection
from repro.core.seismic import exact_top_k, recall_at_k
from repro.data.synthetic import generate_collection, splade_config
from repro.serve.api import Retriever, RetrieverConfig, open_retriever


def main() -> None:
    print("=== 1. synthetic SPLADE collection (MsMarco statistics) ===")
    col = generate_collection(splade_config(n_docs=4000, n_queries=16, seed=0))
    fwd = col.fwd
    print(f"  {fwd.n_docs} docs, dim={fwd.dim}, nnz/doc={fwd.total_nnz/fwd.n_docs:.0f}")

    print("\n=== 2. components compression (paper §2, Table 1 size axis) ===")
    docs = [fwd.components[int(s):int(e)]
            for s, e in zip(fwd.offsets[:-1], fwd.offsets[1:])]
    for name in available_codecs():
        bpc = get_codec(name).bits_per_component(docs)
        print(f"  {name:13s} {bpc:5.2f} bits/component")

    print("\n=== 3. RGB re-ordering (paper §2) ===")
    pi = recursive_graph_bisection(docs, fwd.dim, max_iters=5, leaf_size=32)
    fwd_rgb = fwd.apply_component_permutation(pi)
    docs_rgb = [fwd_rgb.components[int(s):int(e)]
                for s, e in zip(fwd_rgb.offsets[:-1], fwd_rgb.offsets[1:])]
    for name in ("elias_gamma", "zeta", "dotvbyte"):
        b0 = get_codec(name).bits_per_component(docs)
        b1 = get_codec(name).bits_per_component(docs_rgb)
        print(f"  {name:13s} {b0:5.2f} → {b1:5.2f} bits/component "
              f"({100*(1-b1/b0):+.0f}%)")

    print("\n=== 4. serve + index artifact (paper §3, DESIGN.md §7) ===")
    retriever = Retriever.build(
        fwd,
        RetrieverConfig(engine="seismic", codec="dotvbyte", k=10,
                        params=dict(n_postings=1000, block_size=32, cut=8)),
    )
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    ids, _ = retriever.search(Q)
    recalls = [recall_at_k(exact_top_k(fwd, Q[i], 10)[0], np.asarray(ids[i]))
               for i in range(col.n_queries)]
    print(f"  recall@10 = {np.mean(recalls):.3f} with DotVByte rescoring")
    comp_c = fwd.storage_bytes("dotvbyte")["components"]
    comp_u = fwd.storage_bytes("uncompressed")["components"]
    print(f"  forward-index components: {comp_u/2**20:.2f} MiB → "
          f"{comp_c/2**20:.2f} MiB ({100*(1-comp_c/comp_u):.0f}% saved)")

    # build/serve split: save the packed arrays + manifest, reopen in a
    # (conceptually) fresh serving process — no re-encoding, same top-k
    with tempfile.TemporaryDirectory() as tmp:
        art = retriever.save(f"{tmp}/msmarco-mini")
        nbytes = sum(f.stat().st_size for f in art.iterdir())
        reopened = open_retriever(art)
        ids2, _ = reopened.search(Q)
        same = np.array_equal(np.asarray(ids), np.asarray(ids2))
        print(f"  artifact: {nbytes/2**20:.2f} MiB on disk "
              f"(manifest + packed npz), reopened top-k identical: {same}")


if __name__ == "__main__":
    main()
