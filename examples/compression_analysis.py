"""Compression analysis beyond the paper's tables:

* RGB ablation — bits/component per codec, before/after re-ordering,
  for SPLADE and LILSR statistics (paper Table 1 rows, both encoders);
* gap-distribution histogram driving the codec behaviour;
* cross-domain demo: the same codecs compress a GNN edge index (CSR
  neighbour lists are d-gap sequences too — DESIGN.md §6) and recsys
  multi-hot candidate feature lists (the retrieval_cand offline path).

Run:  PYTHONPATH=src python examples/compression_analysis.py
"""

import numpy as np

from repro.core.codecs import available_codecs, get_codec
from repro.core.rgb import recursive_graph_bisection
from repro.data.synthetic import generate_collection, lilsr_config, splade_config


def gap_stats(docs):
    gaps = np.concatenate(
        [np.diff(np.concatenate([[0], d.astype(np.int64)])) for d in docs if len(d)]
    )
    return {
        "mean": float(gaps.mean()),
        "p50": float(np.percentile(gaps, 50)),
        "p99": float(np.percentile(gaps, 99)),
        "frac_1byte": float((gaps < 256).mean()),
    }


def codec_table(docs, title):
    print(f"\n--- {title} ---")
    g = gap_stats(docs)
    print(f"  gaps: mean={g['mean']:.0f} p50={g['p50']:.0f} p99={g['p99']:.0f} "
          f"1-byte-able={100*g['frac_1byte']:.0f}%")
    for name in available_codecs():
        print(f"  {name:13s} {get_codec(name).bits_per_component(docs):5.2f} bits/comp")


def main() -> None:
    for enc, cfg_fn in (("splade", splade_config), ("lilsr", lilsr_config)):
        col = generate_collection(cfg_fn(2500, 4, seed=0))
        fwd = col.fwd
        docs = [fwd.components[int(s):int(e)]
                for s, e in zip(fwd.offsets[:-1], fwd.offsets[1:])]
        codec_table(docs, f"{enc} (identity labels)")
        pi = recursive_graph_bisection(docs, fwd.dim, max_iters=5)
        docs_rgb = [np.sort(pi[d]) for d in docs]
        codec_table(docs_rgb, f"{enc} (after RGB)")

    # --- GNN edge index (DESIGN.md §6: gat-cora applicability) -----------
    rng = np.random.default_rng(0)
    n_nodes = 4096
    adj = [np.sort(rng.choice(n_nodes, size=rng.integers(3, 40), replace=False)
                   ).astype(np.uint32) for _ in range(2000)]
    codec_table(adj, "GNN CSR neighbour lists (edge-index compression)")

    # --- recsys multi-hot candidate features ------------------------------
    fields = [np.sort(rng.choice(65536, size=39, replace=False)).astype(np.uint32)
              for _ in range(2000)]
    codec_table(fields, "recsys candidate multi-hot feature rows")


if __name__ == "__main__":
    main()
