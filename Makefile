# Developer entry points. PYTHONPATH is set per-target so the targets
# work from a clean checkout with no install step.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test check bench bench-fast docs-check

test:            ## tier-1 suite (the CI gate)
	$(PY) -m pytest -x -q

docs-check:      ## audit DESIGN/EXPERIMENTS § cross-references + README make targets
	$(PY) tools/docs_check.py

check: docs-check ## tier-1 suite + tiny Table-1/2/3 benchmark pass + docs audit
	$(PY) -m benchmarks.run --quick

bench:           ## full benchmark sweep (slow)
	$(PY) -m benchmarks.run

bench-fast:      ## reduced-size benchmark sweep
	$(PY) -m benchmarks.run --fast
