# Developer entry points. PYTHONPATH is set per-target so the targets
# work from a clean checkout with no install step.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

ROUNDTRIP_DIR ?= /tmp/repro-serve-roundtrip
ROUNDTRIP_ARGS = --engine all --compare-codecs --n-docs 400 --n-queries 8 --seed 0

.PHONY: test check bench bench-fast docs-check serve-roundtrip kernel-parity shard-parity mutation-parity overlap-parity value-parity perf-gate pipeline-smoke clean

test:            ## tier-1 suite (the CI gate)
	$(PY) -m pytest -x -q

docs-check:      ## audit DESIGN/EXPERIMENTS § cross-references + README make targets
	$(PY) tools/docs_check.py

serve-roundtrip: ## artifact lifecycle smoke: build→save, then load→search in a fresh process (byte-identical top-k, every engine×codec)
	rm -rf $(ROUNDTRIP_DIR)
	$(PY) -m repro.launch.serve $(ROUNDTRIP_ARGS) --save-index $(ROUNDTRIP_DIR)
	$(PY) -m repro.launch.serve $(ROUNDTRIP_ARGS) --load-index $(ROUNDTRIP_DIR)
	rm -rf $(ROUNDTRIP_DIR)

kernel-parity:   ## fused kernels vs jnp in both pallas modes: block scan, rows rescoring, 3-mode top-k id parity, HBM accounting — all engines×codecs
	$(PY) tools/kernel_parity.py

shard-parity:    ## sharded vs unsharded byte-identical top-k (ragged shards included), mmap'd artifact round-trip, on-disk bytes bound — all engines×codecs
	$(PY) tools/shard_parity.py

mutation-parity: ## live-mutation gate: delta segments + tombstones + crash-safe merge byte-identical to a fresh oracle build, pre- and post-merge, monolithic + sharded — all engines×codecs; then a seeded mutate-under-traffic load generator
	$(PY) tools/mutation_parity.py
	$(PY) -m repro.launch.serve --mutate --engine flat --codec streamvbyte --n-docs 60 --n-queries 6 --k 5 --mutations 9

overlap-parity:  ## overlapped serving invisible in the bytes: prefetch on/off parity + the prefetcher actually staging, mesh with live tombstones vs the sequential rotation, queries racing a background merge through the commit flip — all engines×codecs
	$(PY) tools/overlap_parity.py

value-parity:    ## value-codec gate: vq="f16" byte-identical to legacy packs, 3-mode top-k parity at every engine×codec×vq, quantized top-k overlap floors vs the f16 oracle
	$(PY) tools/value_parity.py

perf-gate:       ## NaN-fail when a freshly measured pallas_compiled row is slower than the committed jnp row for the same codec, u8_sq rescoring stops beating f16 on HBM bytes, or prefetch-on p95 regresses past prefetch-off
	$(PY) tools/perf_gate.py

pipeline-smoke:  ## micro-batching scheduler smoke: synthetic trace through the pipeline, every response byte-identical to direct search, ServeStats report
	$(PY) -m repro.launch.serve --pipeline --engine flat --codec streamvbyte --n-docs 300 --n-queries 16 --requests 96 --deadline-us 500
	$(PY) -m repro.launch.serve --pipeline --engine seismic --codec dotvbyte --backend pallas --n-docs 400 --n-queries 8 --requests 48 --n-probe 16

check: docs-check serve-roundtrip kernel-parity shard-parity mutation-parity overlap-parity value-parity perf-gate pipeline-smoke ## tier-1 suite + tiny Table-1..7+kernel benchmark pass + docs audit + artifact + parity + mutation + overlap + value + perf + pipeline gates
	$(PY) -m benchmarks.run --quick

bench:           ## full benchmark sweep (slow)
	$(PY) -m benchmarks.run

bench-fast:      ## reduced-size benchmark sweep
	$(PY) -m benchmarks.run --fast

clean:           ## remove stray bytecode, tool caches, and mutable-index artifacts (generation dirs + CURRENT pointers)
	find . -type d -name __pycache__ -prune -exec rm -rf {} +
	find . -type f \( -name '*.pyc' -o -name '*.pyo' \) -delete
	find . -type d -name 'generation_[0-9][0-9][0-9][0-9]' -prune -exec rm -rf {} +
	find . -type f -name CURRENT -delete
	rm -rf .pytest_cache .ruff_cache .mypy_cache
